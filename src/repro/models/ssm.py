"""Mamba-2 / SSD (state-space duality) blocks.

Implements the chunked SSD algorithm (arXiv:2405.21060 §6) in pure JAX:
intra-chunk quadratic (attention-like, MXU-friendly matmuls) + inter-chunk
linear recurrence over chunk states via ``lax.scan``.  Decode is the exact
single-step recurrence over (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers


def make_ssm_params(rng, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    din, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    conv_dim = din + 2 * ns
    ks = jax.random.split(rng, 4)
    return {
        # order: [z(din) | x(din) | B(ns) | C(ns) | dt(nh)]
        "in_proj": layers.dense_init(ks[0], (D, 2 * din + 2 * ns + nh)),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim),
                                     jnp.float32) * 0.1).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),            # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),     # small initial dt
        "norm": jnp.ones((din,), jnp.float32),
        "out_proj": layers.dense_init(ks[3], (din, D)),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    din, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    z = proj[..., :din]
    xin = proj[..., din:2 * din]
    B = proj[..., 2 * din:2 * din + ns]
    C = proj[..., 2 * din + ns:2 * din + 2 * ns]
    dt = proj[..., 2 * din + 2 * ns:]
    return z, xin, B, C, dt


def _causal_conv(cfg: ModelConfig, p: dict, u: jax.Array) -> jax.Array:
    """u: [B, S, conv_dim] depthwise causal conv, width ssm_conv_width."""
    W = cfg.ssm_conv_width
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * p["conv_w"][i].astype(u.dtype)
              for i in range(W))
    return jax.nn.silu(out + p["conv_b"].astype(u.dtype))


def ssd_chunked(cfg: ModelConfig, xh, dt, A, Bm, Cm, init_state=None,
                shard=lambda x, name: x):
    """Chunked SSD scan.

    xh: [B, S, nh, hd]; dt: [B, S, nh] (post-softplus); A: [nh] (negative);
    Bm/Cm: [B, S, ns].  Returns y [B, S, nh, hd], final_state [B, nh, hd, ns].
    """
    Bsz, S, nh, hd = xh.shape
    ns = Bm.shape[-1]
    cs = min(cfg.ssm_chunk, S)
    S_pad = ((S + cs - 1) // cs) * cs
    nc = S_pad // cs

    xf = xh.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    if S_pad != S:
        # dt=0 padding steps are identity for the recurrence (decay=1, upd=0)
        pad = ((0, 0), (0, S_pad - S), (0, 0))
        xf = jnp.pad(xf, pad + ((0, 0),))
        dtf = jnp.pad(dtf, pad)
        Bf = jnp.pad(Bf, pad)
        Cf = jnp.pad(Cf, pad)

    # reshape into chunks; constrain the chunk dim across `model`
    # (sequence-parallel SSD: the quadratic intra-chunk tensors dominate
    # prefill memory on wide-head hybrids)
    xc = shard(xf.reshape(Bsz, nc, cs, nh, hd), "ssm_chunk")
    dtc = shard(dtf.reshape(Bsz, nc, cs, nh), "ssm_chunk")
    Bc = shard(Bf.reshape(Bsz, nc, cs, ns), "ssm_chunk")
    Cc = shard(Cf.reshape(Bsz, nc, cs, ns), "ssm_chunk")

    da = dtc * A                                           # [B, nc, cs, nh]
    a_cum = jnp.cumsum(da, axis=2)                          # within-chunk cumsum
    a_tot = a_cum[:, :, -1, :]                              # [B, nc, nh]

    # intra-chunk quadratic term: L[i,j] = exp(a_i - a_j) for i >= j
    li = a_cum[:, :, :, None, :]                            # [B,nc,cs,1,nh] (i)
    lj = a_cum[:, :, None, :, :]                            # [B,nc,1,cs,nh] (j)
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li - lj), 0.0)
    CB = shard(jnp.einsum("bnis,bnjs->bnij", Cc, Bc), "ssm_chunk")
    scores = shard(CB[..., None] * L, "ssm_chunk")          # [B,nc,cs,cs,nh]
    xdt = xc * dtc[..., None]                               # [B,nc,cs,nh,hd]
    y_intra = shard(jnp.einsum("bnijh,bnjhd->bnihd", scores, xdt), "ssm_chunk")

    # chunk boundary states: S_n = sum_j exp(a_tot - a_j) dt_j B_j (x_j)^T
    decay_to_end = jnp.exp(a_tot[:, :, None, :] - a_cum)    # [B,nc,cs,nh]
    states = jnp.einsum("bnjs,bnjh,bnjhd->bnhds",
                        Bc, dtc * decay_to_end, xc)         # [B,nc,nh,hd,ns]

    # inter-chunk recurrence over nc (cheap scan)
    h0 = (jnp.zeros((Bsz, nh, hd, ns), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_fn(h, inp):
        st, at = inp                                        # [B,nh,hd,ns], [B,nh]
        h_next = h * jnp.exp(at)[:, :, None, None] + st
        return h_next, h                                    # emit state BEFORE chunk

    (h_final, h_prevs) = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(a_tot, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                   # [B,nc,nh,hd,ns]

    # inter-chunk contribution: y_i += C_i . (exp(a_cum_i) * h_prev)
    y_inter = jnp.einsum("bnis,bnih,bnhds->bnihd", Cc, jnp.exp(a_cum), h_prevs)
    y = (y_intra + y_inter).reshape(Bsz, S_pad, nh, hd)[:, :S]
    return y.astype(xh.dtype), h_final


def ssm_block(cfg: ModelConfig, p: dict, x: jax.Array, init=None,
              shard=lambda x, name: x):
    """Full Mamba-2 block: x [B, S, D] -> (y [B, S, D], (conv_state, ssm_state))."""
    B, S, D = x.shape
    din, ns, nh, hd = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads,
                       cfg.ssm_head_dim)
    proj = x @ p["in_proj"]
    z, xin, Bm, Cm, dt = _split_proj(cfg, proj)
    u = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_state = u[:, -(cfg.ssm_conv_width - 1):, :]        # for decode continuation
    u = _causal_conv(cfg, p, u)
    xin, Bm, Cm = (u[..., :din], u[..., din:din + ns], u[..., din + ns:])
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(B, S, nh, hd)
    y, h_final = ssd_chunked(cfg, xh, dtp, A, Bm, Cm, init_state=init,
                             shard=shard)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * jnp.reshape(
        p["D"], (1, 1, nh, 1)).astype(y.dtype)
    y = y.reshape(B, S, din)
    y = layers.rms_norm_vec(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"], (conv_state, h_final)


def ssm_decode_step(cfg: ModelConfig, p: dict, x: jax.Array, conv_state, ssm_state):
    """Single-token decode. x: [B, D]; conv_state [B, W-1, conv_dim];
    ssm_state [B, nh, hd, ns].  Returns (y [B, D], new_conv, new_ssm)."""
    B, D = x.shape
    din, ns, nh, hd = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads,
                       cfg.ssm_head_dim)
    W = cfg.ssm_conv_width
    proj = x @ p["in_proj"]
    z, xin, Bm, Cm, dt = _split_proj(cfg, proj)
    u_new = jnp.concatenate([xin, Bm, Cm], axis=-1)          # [B, conv_dim]
    window = jnp.concatenate([conv_state, u_new[:, None, :]], axis=1)  # [B, W, cd]
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + p["conv_b"]).astype(x.dtype)
    xin = conv_out[..., :din]
    Bm = conv_out[..., din:din + ns].astype(jnp.float32)
    Cm = conv_out[..., din + ns:].astype(jnp.float32)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B, nh]
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(B, nh, hd).astype(jnp.float32)
    decay = jnp.exp(dtp * A)                                 # [B, nh]
    upd = jnp.einsum("bs,bh,bhd->bhds", Bm, dtp, xh)         # [B,nh,hd,ns]
    new_state = ssm_state.astype(jnp.float32) * decay[..., None, None] + upd
    y = jnp.einsum("bs,bhds->bhd", Cm, new_state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, din).astype(x.dtype)
    y = layers.rms_norm_vec(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"], window[:, 1:, :], new_state.astype(ssm_state.dtype)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    conv = jnp.zeros((batch, cfg.ssm_conv_width - 1,
                      cfg.ssm_d_inner + 2 * cfg.ssm_state), jnp.bfloat16)
    state = jnp.zeros((batch, cfg.ssm_num_heads, cfg.ssm_head_dim,
                       cfg.ssm_state), dtype)
    return conv, state
