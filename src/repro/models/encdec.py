"""Encoder-decoder trunk (whisper-base backbone; conv/mel frontend stubbed).

The encoder consumes precomputed frame embeddings [B, S_enc, D] (stub for the
conv1d+mel frontend, positions assumed baked in); the decoder is a standard
pre-LN transformer with self- + cross-attention and learned positions.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn_mod
from . import layers


def _identity_shard(x, name):
    return x


def make_enc_layer(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 2)
    return {
        "ln1": layers.make_norm_params(cfg, cfg.d_model),
        "attn": attn_mod.make_attn_params(ks[0], cfg),
        "ln2": layers.make_norm_params(cfg, cfg.d_model),
        "mlp": layers.make_mlp_params(ks[1], cfg),
    }


def make_dec_layer(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 3)
    return {
        "ln1": layers.make_norm_params(cfg, cfg.d_model),
        "self_attn": attn_mod.make_attn_params(ks[0], cfg),
        "ln_x": layers.make_norm_params(cfg, cfg.d_model),
        "cross_attn": attn_mod.make_attn_params(ks[1], cfg),
        "ln2": layers.make_norm_params(cfg, cfg.d_model),
        "mlp": layers.make_mlp_params(ks[2], cfg),
    }


def init_params(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 4)
    enc_keys = jax.random.split(ks[0], cfg.num_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": layers.make_embed_params(ks[2], cfg),
        "enc_blocks": jax.vmap(lambda k: make_enc_layer(k, cfg))(enc_keys),
        "enc_norm": layers.make_norm_params(cfg, cfg.d_model),
        "dec_blocks": jax.vmap(lambda k: make_dec_layer(k, cfg))(dec_keys),
        "final_norm": layers.make_norm_params(cfg, cfg.d_model),
        "head": layers.make_head_params(ks[3], cfg),
    }


def encode(cfg: ModelConfig, params: dict, frames: jax.Array, *,
           shard: Callable = _identity_shard) -> jax.Array:
    """frames: [B, S_enc, D] stub embeddings -> encoder states [B, S_enc, D]."""
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = shard(frames, "hidden")

    def block_fn(x, bp):
        h = layers.apply_norm(cfg, bp["ln1"], x)
        x = x + attn_mod.self_attention(cfg, bp["attn"], h, positions,
                                        causal=False)
        h = layers.apply_norm(cfg, bp["ln2"], x)
        x = shard(x + layers.apply_mlp(cfg, bp["mlp"], h), "hidden")
        return x, None

    x, _ = jax.lax.scan(block_fn, x, params["enc_blocks"])
    return layers.apply_norm(cfg, params["enc_norm"], x)


def decode_forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
                   enc_states: jax.Array, *, collect_kv: bool = False,
                   shard: Callable = _identity_shard):
    """Teacher-forced decoder pass. tokens [B, S_dec] -> logits.

    With ``collect_kv`` also returns per-layer (self_kv, cross_kv) caches.
    """
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = layers.embed_tokens(params["embed"], tokens)
    x = x + params["embed"]["pos_dec"][:S][None, :, :].astype(x.dtype)
    x = shard(x, "hidden")

    def block_fn(x, bp):
        aux = {}
        h = layers.apply_norm(cfg, bp["ln1"], x)
        q, k, v = attn_mod.qkv_proj(cfg, bp["self_attn"], h, positions)
        if collect_kv:
            aux["self_kv"] = (k, v)
        from ..kernels import ops
        o = ops.attention(q, k, v, causal=True)
        x = x + o.reshape(B, S, -1) @ bp["self_attn"]["wo"]
        h = layers.apply_norm(cfg, bp["ln_x"], x)
        mem_k, mem_v = attn_mod.encoder_kv(cfg, bp["cross_attn"], enc_states)
        if collect_kv:
            aux["cross_kv"] = (mem_k, mem_v)
        x = x + attn_mod.cross_attention(cfg, bp["cross_attn"], h, mem_k, mem_v)
        h = layers.apply_norm(cfg, bp["ln2"], x)
        x = shard(x + layers.apply_mlp(cfg, bp["mlp"], h), "hidden")
        return x, (aux if collect_kv else None)

    x, caches = jax.lax.scan(block_fn, x, params["dec_blocks"])
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.apply_head(cfg, params["head"], params["embed"], x)
    return shard(logits, "logits"), caches


def forward(cfg: ModelConfig, params: dict, frames: jax.Array,
            tokens: jax.Array, *, shard: Callable = _identity_shard,
            remat: str = "none"):
    """Full enc-dec pass -> logits [B, S_dec, Vp]."""
    enc = encode(cfg, params, frames, shard=shard)
    logits, _ = decode_forward(cfg, params, tokens, enc, shard=shard)
    return logits


def loss_fn(cfg: ModelConfig, params: dict, frames: jax.Array,
            tokens: jax.Array, targets: jax.Array, *,
            shard: Callable = _identity_shard, remat: str = "none") -> jax.Array:
    logits = forward(cfg, params, frames, tokens, shard=shard, remat=remat)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
