"""Disaggregated prefill/decode cell-ratio sweep: the TTFT knee, priced.

Sweeps offered request rate x prefill-cell count on the paper-scale
simulator (deepseek-v3 analytic data plane, 32 instances, real control
plane) over the >=5%-long mixed trace — the workload where monolithic
prefill is the head-of-line hazard.  Both modes charge prefill CHUNKED
(``charge_prefill=True``): colocated drains chunks round-robin on the
global clock (prefill compute steals decode iterations), disaggregated
streams them from dedicated cells with every handoff chunk priced as a
KV re-shard over the cell->decode link class, overlapped with the next
chunk's compute.

Headline metric is the **short-request TTFT knee**: the highest offered
rate at which >= ``TARGET`` of all *submitted* short requests (prompt <
``SHORT_MAX``) get their first token within ``TTFT_SLO``.  Unfinished
shorts count as violations — the denominator is what arrived, not what
the scheduler deigned to finish — so colocated cannot flatter its curve
by starving the queue.  Full-scan knee (attainment is not monotone in
rate under admission/recovery dynamics), same convention as
``slo_sweep.py``.

Emits ``BENCH_disagg_sweep.json`` (or ``--out``).  ``--smoke`` shrinks
the grid to the CI cells gated by ``check_regression.py``; the full
sweep (more ratios + a long_ratio=0 control separating colocated
prefill-serialization loss from long-tail pressure) runs nightly.
Exits 1 unless
the best disaggregated cell ratio's knee is STRICTLY above colocated on
the long mix — the disaggregation claim is asserted, not eyeballed.

  PYTHONPATH=src python benchmarks/disagg_sweep.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "benchmarks")
from common import BUCKETS, CFG, N_INST, PER_NODE  # noqa: E402

from repro.core.scheduler import DualBalancedScheduler  # noqa: E402
from repro.serving.simulator import ClusterSimulator  # noqa: E402
from repro.serving.workload import make_workload  # noqa: E402

TTFT_SLO = 0.3          # s, first token deadline for the short tier
TARGET = 0.9            # attainment the knee must clear
SHORT_MAX = 10_000      # tokens; below this a request is "short tier"
LONG_RATIO = 0.05       # the paper's >=5%-long mixed trace
DURATION = 3.0          # s of offered arrivals per point
HORIZON = 60.0          # s simulated; unfinished-by-horizon = violation
KV_CAP = 1_000_000      # per-instance KV tokens (paper scale)

# cell counts: 0 = colocated baseline; the disaggregated ratios carve
# prefill cells out of the SAME 32 instances, so the decode side shrinks
# — the win has to pay for its own capacity loss
CELLS_FULL = (0, 4, 8)
CELLS_SMOKE = (0, 8)
RATES_FULL = (5, 10, 20, 40, 60, 120)
RATES_SMOKE = (10, 20, 40)
CONTROL_RATES = (10, 40)    # long_ratio=0 control points (full mode only)


def run_point(cells: int, rate: float, long_ratio: float) -> dict:
    """One (cell-count, rate) point: short-tier TTFT attainment over
    SUBMITTED shorts (missing first token == inf TTFT == violation)."""
    sched = DualBalancedScheduler(buckets=BUCKETS)
    sim = ClusterSimulator(CFG, sched, num_instances=N_INST,
                           instances_per_node=PER_NODE,
                           kv_capacity_tokens=KV_CAP, multi_step=4,
                           charge_prefill=True, prefill_cells=cells)
    wl = make_workload("mixed", rate=rate, duration=DURATION,
                       long_ratio=long_ratio, seed=0)
    res = sim.run(wl, horizon=HORIZON)
    fin = {r.rid: r for r in res.finished if r.status == "finished"}
    shorts = [r for r in wl.requests if r.prompt_len < SHORT_MAX]
    tt = []
    for q in shorts:
        r = fin.get(q.rid)
        tt.append(r.token_times[0] - q.arrival
                  if r is not None and r.token_times else float("inf"))
    tt.sort()
    n = len(tt)
    served = sum(1 for t in tt if t != float("inf"))
    return {
        "rate": rate,
        "n_short": n,
        "short_served": served,
        "ttft_attainment": sum(1 for t in tt if t <= TTFT_SLO) / n,
        "ttft_p50": tt[n // 2],
        "ttft_p99": tt[min(n - 1, int(n * 0.99))],
        "finished": len(fin),
        "submitted": len(wl.requests),
    }


def knee(rows: list[dict]) -> float:
    """Highest swept rate with attainment >= TARGET (full scan)."""
    ok = [r["rate"] for r in rows if r["ttft_attainment"] >= TARGET]
    return max(ok) if ok else 0.0


def sweep(smoke: bool) -> dict:
    cells_grid = CELLS_SMOKE if smoke else CELLS_FULL
    rates = RATES_SMOKE if smoke else RATES_FULL
    out = {}
    for cells in cells_grid:
        mode = "colocated" if cells == 0 else f"cells{cells}"
        rows = []
        t0 = time.time()
        for rate in rates:
            rows.append(run_point(cells, rate, LONG_RATIO))
        k = knee(rows)
        out[mode] = {"prefill_cells": cells, "knee_rate": k, "rows": rows}
        att = {r["rate"]: round(r["ttft_attainment"], 3) for r in rows}
        print(f"sim  long={LONG_RATIO:.0%} {mode:10s} knee={k:>6} "
              f"att={att} ({time.time() - t0:.0f}s)", flush=True)
    return out


def sweep_control(cells_grid: tuple) -> dict:
    """long_ratio=0 control: separates the two effects.  Colocated
    serializes ALL prefill chunks on the global clock, so it collapses
    even with no longs at all (pure serialization loss); the long tail
    then shows up as the extra attainment drop the *intermediate* cell
    ratio takes when longs enter the mix (cells4 at the knee rate: ~0.95
    attainment at 0% long vs ~0.58 at 5%)."""
    out = {}
    for cells in cells_grid:
        mode = "colocated" if cells == 0 else f"cells{cells}"
        rows = [run_point(cells, rate, 0.0) for rate in CONTROL_RATES]
        out[mode] = {"prefill_cells": cells, "rows": rows}
        att = {r["rate"]: round(r["ttft_attainment"], 3) for r in rows}
        print(f"sim  long=0%  {mode:10s} (control) att={att}", flush=True)
    return out


def check_headline(curves: dict) -> list[str]:
    """Disaggregation must strictly improve the TTFT knee over colocated
    on the long mix, and the colocated knee must be bracketed by the
    grid (a 0-vs-0 'win' would be vacuous)."""
    failures = []
    colo = curves["colocated"]["knee_rate"]
    disagg = {m: row["knee_rate"] for m, row in curves.items()
              if m != "colocated"}
    if colo <= 0:
        failures.append(
            f"colocated knee not bracketed by the rate grid (knee={colo}); "
            "add a lower rate so the comparison is meaningful")
    best_mode, best = max(disagg.items(), key=lambda kv: kv[1])
    if not best > colo:
        failures.append(
            f"disaggregated TTFT knee is not strictly above colocated on "
            f"the {LONG_RATIO:.0%}-long mix: best {best_mode}={best} vs "
            f"colocated={colo}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI grid (gated by check_regression.py)")
    ap.add_argument("--out", default="BENCH_disagg_sweep.json")
    args = ap.parse_args()

    t0 = time.time()
    curves = sweep(args.smoke)
    rep = {
        "smoke": bool(args.smoke),
        "ttft_slo": TTFT_SLO,
        "target": TARGET,
        "long_ratio": LONG_RATIO,
        "num_instances": N_INST,
        "curves": curves,
    }
    if not args.smoke:
        rep["control_long0"] = sweep_control(CELLS_FULL)
    rep["elapsed_s"] = round(time.time() - t0, 1)

    with open(args.out, "w") as f:
        json.dump(rep, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({rep['elapsed_s']}s)")

    failures = check_headline(curves)
    for msg in failures:
        print(f"HEADLINE FAIL: {msg}", flush=True)
    if failures:
        return 1
    knees = {m: row["knee_rate"] for m, row in curves.items()}
    print(f"headline OK: disaggregated TTFT knee strictly beats colocated "
          f"on the {LONG_RATIO:.0%}-long mix ({knees})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
