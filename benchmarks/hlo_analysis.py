"""Post-SPMD HLO text analyzer: FLOPs / bytes / collective bytes with
while-loop trip-count correction.

``compiled.cost_analysis()`` counts a loop body ONCE (verified empirically:
scan of 8 matmuls reports 1/8 of the FLOPs), which would wildly undercount
scan-over-layers models.  This analyzer parses ``compiled.as_text()`` (the
PER-DEVICE SPMD module), builds the computation call graph, extracts each
while loop's trip count from its condition's comparison constant, and
multiplies body costs accordingly.

Costs:
  flops       — 2*prod(out)*prod(contracted lhs dims) per dot (incl. inside
                fusions); elementwise ops are ignored (matmul-dominated).
  bytes       — sum of operand+result bytes of top-level instructions
                (fusion internals excluded — matches XLA's bytes-accessed).
  collectives — per-device ring-traffic estimates by op kind and replica
                group size g:
                  all-gather / reduce-scatter: in * (g-1)  /  in * (g-1)/g
                  all-reduce: 2 * in * (g-1)/g
                  all-to-all: in * (g-1)/g,  collective-permute: in
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclass
class Computation:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    # (callee, kind) edges: kind in {"while", "call", "fusion", "cond"}
    calls: list = field(default_factory=list)
    trip_hint: int = 0          # if this is a while BODY: trip count
    const_ints: list = field(default_factory=list)
    # fusion call sites deferred to analyze(): (callee, [operand bytes], out)
    fusion_sites: list = field(default_factory=list)
    # param name -> consumer opcodes + sliced-access bytes (for fusion params)
    param_names: list = field(default_factory=list)
    consumers: dict = field(default_factory=lambda: defaultdict(list))

    def param_access(self) -> list:
        """Per-parameter actual access bytes, or None for full reads.

        A fusion parameter consumed ONLY by windowing ops (slice /
        dynamic-slice / gather) is charged the window bytes, not the whole
        operand — stacked per-layer weights sliced inside scan bodies would
        otherwise be charged per iteration."""
        out = []
        for pname in self.param_names:
            cons = self.consumers.get(pname, [])
            if cons and all(op in ("slice", "dynamic-slice", "gather")
                            for op, _ in cons):
                out.append(sum(b for _, b in cons))
            else:
                out.append(None)
        return out


_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-_]+) \(.*?\) -> .* \{")
_OP_RE = re.compile(
    r"^\s*(?:ROOT )?%?([\w\.\-_]+) = (\([^)]*\)|[\w\[\],\{\} ]+?) ([\w\-]+)\((.*)")


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    op_shapes: dict[str, str] = {}

    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            op_shapes = {}
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            # parameters: "%p = f32[..] parameter(0)" matches _OP_RE; skip rest
            continue
        name, type_str, opcode, rest = m.groups()
        op_shapes[name] = type_str
        if opcode in ("bitcast", "get-tuple-element", "tuple", "after-all",
                      "partition-id", "replica-id", "iota", "reshape",
                      "broadcast", "copy"):
            # zero-cost / layout-only ops.  `copy` is excluded because the
            # XLA:CPU artifact copies scan carries per iteration; the TPU
            # target elides them via in-place buffer aliasing (donated
            # carries), so charging them would misstate the TPU roofline.
            continue
        if opcode == "constant":
            cm = re.match(r"(\d+)\)", rest)
            if cm:
                cur.const_ints.append(int(cm.group(1)))
            continue
        if opcode == "parameter":
            cur.param_names.append(name)
            continue

        out_bytes = _shape_bytes(type_str)
        # operand shapes: resolve %refs against recorded shapes
        opnds = re.findall(r"%([\w\.\-_]+)", rest.split(", calls=")[0]
                           .split(", body=")[0])
        in_bytes = sum(_shape_bytes(op_shapes.get(o, "")) for o in opnds)
        for o in opnds:
            cur.consumers[o].append((opcode, out_bytes))

        if opcode in ("slice", "dynamic-slice", "gather"):
            # actual access = the extracted window, not the whole operand
            # (stacked-layer weights sliced inside scans would otherwise
            # count the full stack once per iteration)
            cur.bytes += 2 * out_bytes
            continue
        if opcode in ("dynamic-update-slice", "scatter"):
            # read-modify-write of the update window; the base buffer
            # aliases in place
            upd = _shape_bytes(op_shapes.get(opnds[1], "")) if len(opnds) > 1 \
                else out_bytes
            cur.bytes += 3 * upd
            continue
        if opcode == "dot":
            flops = _dot_flops(type_str, rest, op_shapes)
            cur.flops += flops
            cur.bytes += in_bytes + out_bytes
        elif opcode == "fusion":
            fm = re.search(r"calls=%?([\w\.\-_]+)", rest)
            if fm:
                cur.calls.append((fm.group(1), "fusion"))
                cur.fusion_sites.append(
                    (fm.group(1), name,
                     [_shape_bytes(op_shapes.get(o, "")) for o in opnds],
                     out_bytes))
            else:
                cur.bytes += in_bytes + out_bytes
        elif opcode == "while":
            bm = re.search(r"body=%?([\w\.\-_]+)", rest)
            cm2 = re.search(r"condition=%?([\w\.\-_]+)", rest)
            if bm:
                cur.calls.append((bm.group(1), "while"))
            if cm2 and bm:
                cur.calls.append((cm2.group(1), f"cond:{bm.group(1)}"))
        elif opcode in ("call", "custom-call", "conditional"):
            fm = re.search(r"(?:calls|to_apply)=%?([\w\.\-_]+)", rest)
            if fm:
                cur.calls.append((fm.group(1), "call"))
            cur.bytes += in_bytes + out_bytes
        elif opcode.rstrip(".0123456789") in _COLLECTIVES or any(
                opcode.startswith(c) for c in _COLLECTIVES):
            kind = next(c for c in _COLLECTIVES if opcode.startswith(c))
            g = _group_size(rest)
            if kind == "all-gather":
                traffic = out_bytes * (g - 1) / max(g, 1)
            elif kind == "reduce-scatter":
                traffic = in_bytes * (g - 1) / max(g, 1)
            elif kind == "all-reduce":
                traffic = 2 * in_bytes * (g - 1) / max(g, 1)
            elif kind == "all-to-all":
                traffic = in_bytes * (g - 1) / max(g, 1)
            else:                            # collective-permute
                traffic = in_bytes
            cur.coll_bytes += traffic
            cur.coll_by_kind[kind] += traffic
            cur.bytes += in_bytes + out_bytes
        else:
            cur.bytes += in_bytes + out_bytes
    return comps


def _dot_flops(type_str: str, rest: str, op_shapes: dict) -> float:
    _, out_dims = _shape_dims(type_str)
    out_n = 1
    for d in out_dims:
        out_n *= d
    lm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
    opnds = re.findall(r"%([\w\.\-_]+)", rest)
    k = 1
    if lm and opnds:
        _, lhs_dims = _shape_dims(op_shapes.get(opnds[0], ""))
        for d in lm.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                k *= lhs_dims[int(d)]
    return 2.0 * out_n * k


def _group_size(rest: str) -> int:
    gm = re.search(r"replica_groups=\{\{([^}]*)\}", rest)
    if gm:
        return len([x for x in gm.group(1).split(",") if x.strip()])
    gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)  # iota format
    if gm:
        return int(gm.group(2))
    gm = re.search(r"source_target_pairs=", rest)
    return 2 if gm else 1


def _trip_count(cond: Computation) -> int:
    """Trip count from the condition's comparison constant (scan loops
    compare the induction variable against a compile-time constant)."""
    return max(cond.const_ints, default=1)


def analyze(text: str) -> dict:
    """Returns trip-count-corrected totals for the entry computation."""
    comps = parse_hlo(text)
    entry = next((c for c in comps if "main" in c), None)
    if entry is None:
        entry = next(iter(comps))
    trips: dict[str, int] = {}
    for c in comps.values():
        for callee, kind in c.calls:
            if kind.startswith("cond:"):
                body = kind.split(":", 1)[1]
                if callee in comps:
                    trips[body] = _trip_count(comps[callee])

    memo: dict[str, tuple] = {}

    def total(name: str, depth=0) -> tuple:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 50:
            return (0.0, 0.0, 0.0, defaultdict(float))
        c = comps[name]
        f, b, cb = c.flops, c.bytes, c.coll_bytes
        for callee, opname, opnd_bytes, out_b in c.fusion_sites:
            short = opname
            if short.startswith(("convert", "copy", "bitcast")):
                # dtype-convert / layout fusions: XLA:CPU materialises f32
                # copies of bf16 operands before dots; the TPU MXU consumes
                # bf16 natively, so these are compilation artifacts (the
                # consuming op still charges its operand reads).
                continue
            if short.startswith("dynamic-update-slice"):
                # in-place windowed write on TPU: charge the window
                # (= everything but the aliased base buffer), not the pool
                win = sum(opnd_bytes) - max(opnd_bytes, default=0)
                b += 3 * win
                continue
            acc = comps[callee].param_access() if callee in comps else []
            site = out_b
            for i, full in enumerate(opnd_bytes):
                a = acc[i] if i < len(acc) else None
                site += min(a, full) if a is not None else full
            b += site
        kinds = defaultdict(float, c.coll_by_kind)
        for callee, kind in c.calls:
            if kind.startswith("cond:"):
                continue
            cf, cby, ccb, ck = total(callee, depth + 1)
            mult = trips.get(callee, 1) if kind == "while" else 1
            f += mult * cf
            if kind != "fusion":
                # fusion internals don't touch memory separately — the call
                # site's operand/result bytes already cover them
                b += mult * cby
            cb += mult * ccb
            for k, v in ck.items():
                kinds[k] += mult * v
        memo[name] = (f, b, cb, kinds)
        return memo[name]

    f, b, cb, kinds = total(entry)
    return {"flops": f, "bytes": b, "collective_bytes": cb,
            "collective_by_kind": dict(kinds),
            "num_computations": len(comps),
            "while_trips": trips}


def analyze_file(path: str) -> dict:
    import zstandard
    with open(path, "rb") as fh:
        text = zstandard.ZstdDecompressor().decompress(fh.read()).decode()
    return analyze(text)
