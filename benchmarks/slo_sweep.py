"""Goodput-vs-rate SLO sweep: the closed serving loop, both execution tiers.

Sweeps offered request rate x workload mix for three placement policies —
DCP (``nanocp``), static uniform CP, and instance-local (``least_batch``) —
with the FULL closed loop engaged: ``AdmissionController`` deadlines,
queue-overflow rejection, deadline shedding, and preemption-by-relaxation.
Every submitted request lands in exactly one typed outcome, and the honest
metrics (``repro.serving.metrics``) count unserved requests as violations,
so the curves cannot be flattered by dropping load.

Two tiers, same trace shape and the same knee-finding code path
(``metrics.max_sustainable_rate``, full-scan — attainment is not monotone
in offered rate):

* **sim**: paper scale (deepseek-v3 analytic data plane, 32 instances,
  real control plane) via ``ClusterSimulator``; mixes are the paper's
  mixed traces (1% / 5% long).
* **engine**: the REAL ``NanoCPEngine`` (tinyllama reduced, 2 instances,
  tp=2 on 8 host devices) on the deterministic virtual model clock
  (``slo.run_engine_clocked``) — tokens, page tables, admission,
  preemption and re-shard collectives all real, so the DCP-vs-static-CP
  separation is measured on actual KV fragmentation, not on the model.

Emits ``BENCH_slo_sweep.json`` (or ``--out``).  ``--smoke`` shrinks the
grid to the CI cells gated by ``check_regression.py``; the full sweep runs
nightly.  Exits 1 if DCP's max sustainable rate is not STRICTLY above both
baselines in every (tier, mix) — the headline claim is asserted, not
eyeballed.

  PYTHONPATH=src python benchmarks/slo_sweep.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import sys
import time

# --------------------------------------------------------------------- #
# simulator tier: paper scale, analytic data plane, real control plane
# --------------------------------------------------------------------- #
SIM_TPOT_SLO = 0.035        # s/token, queueing-inclusive (Fig. 12 style)
SIM_TTFT_SLO = 0.5          # s, short-tier admission deadline
SIM_TARGET = 0.99
SIM_POLICIES = ("nanocp", "least_batch", "cp4")
SIM_RATES_FULL = (200, 300, 400, 500)
SIM_RATES_SMOKE = (300, 400)
SIM_MIXES_FULL = (0.01, 0.05)
SIM_MIXES_SMOKE = (0.05,)

# --------------------------------------------------------------------- #
# engine tier: real NanoCPEngine on the virtual model clock.  The box is
# deliberately tight (192-token KV per instance, 16-token pages) so page
# fragmentation binds: a 40-token short costs 3 frames under DCP degree 1
# but 4+ under forced CP2, which is exactly the resident-concurrency loss
# the paper attributes to static CP.  "Rate" is 1/gap of the arrival
# interleave; the knee grid brackets the measured saturation point.
# --------------------------------------------------------------------- #
ENG_TPOT_SLO = 0.0006       # s/token on the model clock (iter ~0.2ms)
ENG_TTFT_SLO = 0.0025       # s; sits between nanocp's and cp2's TTFT tails
ENG_TARGET = 0.99           # 32-request trace: zero violations allowed
ENG_POLICIES = ("nanocp", "least_batch", "cp2")
ENG_RATES_FULL = (2000, 2500, 3333)
ENG_RATES_SMOKE = (2500, 3333)
ENG_TRACE = dict(n_short=30, n_long=2, short_len=40, long_len=200, decode=6)
ENG_KV_CAP = 192
ENG_PAGE = 16
ENG_LONG_THRESHOLD = 100    # tokens: 40-token shorts tier 0, 200-token longs tier 1


def _mk_admission(AdmissionController, *, ttft_slo, long_threshold,
                  max_queue=None):
    return AdmissionController(ttft_slo=ttft_slo,
                               long_threshold=long_threshold,
                               max_queue=max_queue, preempt=True)


def _curve_row(best, stats, summaries):
    return {
        "max_rate": float(best),
        "knee_attainment": (summaries[best]["attainment"]
                            if best in summaries else None),
        "curve": {str(r): summaries[r] for r in sorted(summaries)},
    }


def sweep_sim(smoke: bool) -> dict:
    from repro.core.scheduler import AdmissionController
    from repro.serving import metrics, slo
    from repro.serving.simulator import ClusterSimulator
    from repro.serving.workload import make_workload

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import CFG, N_INST, PER_NODE, make_scheduler

    rates = SIM_RATES_SMOKE if smoke else SIM_RATES_FULL
    mixes = SIM_MIXES_SMOKE if smoke else SIM_MIXES_FULL
    out = {}
    for ratio in mixes:
        mix_key = f"mixed_{int(ratio * 100)}pct"
        out[mix_key] = {}
        for name in SIM_POLICIES:
            summaries = {}

            def run_at(rate, _name=name, _ratio=ratio, _summ=summaries):
                sched = make_scheduler(_name)
                sched.admission = _mk_admission(
                    AdmissionController, ttft_slo=SIM_TTFT_SLO,
                    long_threshold=100_000, max_queue=512)
                sim = ClusterSimulator(
                    CFG, sched, num_instances=N_INST,
                    instances_per_node=PER_NODE,
                    kv_capacity_tokens=1_000_000, multi_step=4)
                wl = make_workload("mixed", rate=rate, duration=4.0,
                                   long_ratio=_ratio, seed=0)
                fin, sub, res = slo.run_sim_trace(sim, wl, horizon=45.0)
                s = slo.summarize(fin, sub, slo=SIM_TPOT_SLO,
                                  ttft_slo=SIM_TTFT_SLO)
                s["preemptions"] = res.preemptions
                _summ[rate] = s
                return fin, sub

            t0 = time.time()
            best, _ = metrics.max_sustainable_rate(
                run_at, rates, slo=SIM_TPOT_SLO, target=SIM_TARGET,
                ttft_slo=SIM_TTFT_SLO)
            out[mix_key][name] = _curve_row(best, None, summaries)
            print(f"sim  {mix_key:12s} {name:12s} max_rate={best:>6} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return out


def _build_engine(policy: str):
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.configs import CONFIGS, reduced
    from repro.core.bucketing import CPBuckets, ShapeBuckets
    from repro.core.scheduler import (AdmissionController,
                                      DualBalancedScheduler,
                                      LeastBatchScheduler,
                                      UniformCPScheduler)
    from repro.models import init_params
    from repro.serving.engine import NanoCPEngine
    from repro.serving.simulator import ClusterSimulator

    cfg = reduced(CONFIGS["tinyllama-1.1b"], vocab_size=256)
    params = jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        init_params(jax.random.PRNGKey(0), cfg))
    mesh = compat.make_mesh((2, 2), ("data", "model"))
    buckets = CPBuckets(edges=(128,), degrees=(1, 2))
    kw = dict(max_batch_per_instance=8)
    if policy == "nanocp":
        sched = DualBalancedScheduler(buckets=buckets, kv_reserve=16, **kw)
    elif policy == "least_batch":
        sched = LeastBatchScheduler(**kw)
    elif policy == "cp2":
        sched = UniformCPScheduler(cp=2, **kw)
    else:
        raise ValueError(policy)
    sched.admission = _mk_admission(
        AdmissionController, ttft_slo=ENG_TTFT_SLO,
        long_threshold=ENG_LONG_THRESHOLD)
    eng = NanoCPEngine(
        cfg, params, mesh, num_instances=2, instances_per_node=2, tp=2,
        kv_capacity_tokens=ENG_KV_CAP, page_size=ENG_PAGE, buckets=buckets,
        shape_buckets=ShapeBuckets(m_buckets=(1, 2, 4, 8),
                                   s_buckets=(0, 1, 2, 4), window=2),
        scheduler=sched, max_slots_per_instance=8, pipeline=False)
    shadow = ClusterSimulator(cfg, sched, num_instances=2,
                              instances_per_node=2,
                              kv_capacity_tokens=ENG_KV_CAP,
                              page_size=ENG_PAGE)
    return eng, shadow


def sweep_engine(smoke: bool) -> dict:
    from repro.serving import metrics, slo

    rates = ENG_RATES_SMOKE if smoke else ENG_RATES_FULL
    mix_key = f"tiny_{ENG_TRACE['n_short']}s_{ENG_TRACE['n_long']}l"
    out = {mix_key: {}}
    for name in ENG_POLICIES:
        summaries = {}

        def run_at(rate, _name=name, _summ=summaries):
            eng, shadow = _build_engine(_name)
            wl = slo.make_tiny_trace(gap=1.0 / rate, **ENG_TRACE)
            fin, sub, now = slo.run_engine_clocked(eng, wl, shadow=shadow,
                                                   max_iters=1500)
            s = slo.summarize(fin, sub, slo=ENG_TPOT_SLO,
                              ttft_slo=ENG_TTFT_SLO, duration=now)
            s["preemptions"] = eng.hot_path_stats["preemptions"]
            _summ[rate] = s
            return fin, sub

        t0 = time.time()
        best, _ = metrics.max_sustainable_rate(
            run_at, rates, slo=ENG_TPOT_SLO, target=ENG_TARGET,
            ttft_slo=ENG_TTFT_SLO)
        out[mix_key][name] = _curve_row(best, None, summaries)
        print(f"eng  {mix_key:12s} {name:12s} max_rate={best:>6} "
              f"({time.time() - t0:.0f}s)", flush=True)
    return out


def check_headline(curves: dict) -> list[str]:
    """DCP must beat BOTH baselines strictly in every (tier, mix)."""
    failures = []
    for tier, mixes in curves.items():
        for mix, pols in mixes.items():
            dcp = pols["nanocp"]["max_rate"]
            for base, row in pols.items():
                if base == "nanocp":
                    continue
                if not dcp > row["max_rate"]:
                    failures.append(
                        f"{tier}/{mix}: nanocp max_rate {dcp} is not "
                        f"strictly above {base} ({row['max_rate']})")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_slo_sweep.json")
    args = ap.parse_args()

    rep = {
        "smoke": bool(args.smoke),
        "slo": {
            "sim": {"tpot": SIM_TPOT_SLO, "ttft": SIM_TTFT_SLO,
                    "target": SIM_TARGET},
            "engine": {"tpot": ENG_TPOT_SLO, "ttft": ENG_TTFT_SLO,
                       "target": ENG_TARGET},
        },
        "curves": {
            "sim": sweep_sim(args.smoke),
            "engine": sweep_engine(args.smoke),
        },
    }
    with open(args.out, "w") as f:
        json.dump(rep, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")

    failures = check_headline(rep["curves"])
    if failures:
        print("\nSLO sweep headline FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("headline OK: DCP max sustainable rate strictly above both "
          "baselines in every (tier, mix)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
