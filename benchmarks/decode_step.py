"""Decode hot-path benchmark: per-iteration HOST overhead of the engine.

Measures, per decode iteration and per AOT bucket (M, S, MB, W):
  * lower_us     — routing-table lowering (``routing.lower_plan``)
  * tables_us    — host->device table upload (``routing.as_device_arrays``)
  * dispatch_us  — engine-reported async dispatch time (0 on engines that
                   don't instrument; the seed engine blocks inside step)
  * harvest_us   — engine-reported token readback/bookkeeping time
  * step_us      — full ``engine.step`` wall time (host + device)

Admission iterations (prefill + KV migration) are reported separately from
steady-state iterations — the tentpole target is the steady-state numbers.

Works against both the pre- and post-refactor engine: lowering/table upload
are timed by wrapping the ``repro.core.routing`` entry points, so the same
script produces the before/after comparison.  Emits ``BENCH_decode_step.json``
at the repo root (or ``--out``).

  PYTHONPATH=src python benchmarks/decode_step.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import statistics
import time


def _wrap_timed(module, name, sink):
    """Patch ``module.name`` with a wall-clock-accumulating wrapper."""
    orig = getattr(module, name)

    def wrapped(*a, **kw):
        t0 = time.perf_counter()
        out = orig(*a, **kw)
        sink.append((time.perf_counter() - t0) * 1e6)
        return out

    setattr(module, name, wrapped)
    return orig


def _summ(xs):
    if not xs:
        return {"mean_us": 0.0, "p50_us": 0.0, "p99_us": 0.0, "n": 0}
    xs = sorted(xs)
    return {
        "mean_us": statistics.fmean(xs),
        "p50_us": xs[len(xs) // 2],
        "p99_us": xs[min(len(xs) - 1, int(len(xs) * 0.99))],
        "n": len(xs),
    }


def run_bench(smoke: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import CONFIGS, reduced
    from repro.core import routing
    from repro.core.bucketing import CPBuckets, ShapeBuckets
    from repro.models import init_params
    from repro.serving.engine import NanoCPEngine

    cfg = reduced(CONFIGS["tinyllama-1.1b"], num_layers=2, vocab_size=256)
    rng = jax.random.PRNGKey(0)
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          init_params(rng, cfg))
    from repro import compat
    mesh = compat.make_mesh((4, 2), ("data", "model"))

    eng = NanoCPEngine(
        cfg, params, mesh, num_instances=4, instances_per_node=4,
        kv_capacity_tokens=16384, page_size=16,
        buckets=CPBuckets(edges=(100, 256), degrees=(1, 2, 3)),
        shape_buckets=ShapeBuckets(m_buckets=(1, 2, 4, 8, 16),
                                   s_buckets=(0, 1, 2, 4, 8, 16, 32),
                                   window=4))

    # mixed short/long prompts -> several (M, S) buckets get exercised;
    # the non-smoke run fills all 4 instances to a realistic decode batch
    rng_np = np.random.default_rng(0)
    if smoke:
        lengths = [50, 300, 120]
        max_new = 8
    else:
        lengths = [int(rng_np.integers(40, 320)) for _ in range(48)]
        max_new = 48
    for L in lengths:
        eng.add_request(rng_np.integers(0, 256, (L,)), max_new_tokens=max_new)

    lower_sink, tables_sink = [], []
    _wrap_timed(routing, "lower_plan", lower_sink)
    _wrap_timed(routing, "as_device_arrays", tables_sink)

    per_iter = []
    it = 0
    max_iters = 20 if smoke else 120
    while (eng.cluster.active or eng.cluster.waiting
           or getattr(eng, "_inflight", None)) and it < max_iters:
        waiting_before = len(eng.cluster.waiting)
        l0, t0 = len(lower_sink), len(tables_sink)
        w0 = time.perf_counter()
        eng.step()
        step_us = (time.perf_counter() - w0) * 1e6
        timings = getattr(eng, "timings", None)
        rec = {
            "iter": it,
            "admission": waiting_before > len(eng.cluster.waiting),
            "step_us": step_us,
            "lower_us": sum(lower_sink[l0:]),
            "tables_us": sum(tables_sink[t0:]),
            "bucket": getattr(eng, "last_bucket", None),
        }
        if timings:
            for k in ("dispatch_us", "harvest_us", "prefill_us"):
                if timings.get(k) is not None:
                    rec[k] = timings[k]
        per_iter.append(rec)
        it += 1

    steady = [r for r in per_iter if not r["admission"]]
    admit = [r for r in per_iter if r["admission"]]
    by_bucket = {}
    for r in steady:
        if r["bucket"] is None:
            continue
        by_bucket.setdefault(str(tuple(r["bucket"])), []).append(r)

    def agg(rows):
        out = {}
        for k in ("step_us", "lower_us", "tables_us", "dispatch_us",
                  "harvest_us"):
            xs = [r[k] for r in rows if k in r]
            if xs:
                out[k] = _summ(xs)
        return out

    report = {
        "bench": "decode_step",
        "smoke": smoke,
        "iterations": it,
        "finished_requests": len(eng.finished),
        "steady_state": agg(steady),
        "admission": agg(admit),
        "per_bucket": {k: agg(v) for k, v in sorted(by_bucket.items())},
        "aot": eng.aot.stats.as_dict(),
    }
    # engine-level donation / transfer accounting (post-refactor engines)
    for attr in ("donation_stats", "hot_path_stats"):
        v = getattr(eng, attr, None)
        if v is not None:
            report[attr] = dict(v)
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (few requests, few iterations)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: <repo>/BENCH_decode_step.json)")
    args = ap.parse_args()
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_decode_step.json")
    report = run_bench(smoke=args.smoke)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    ss = report["steady_state"]
    print(f"decode_step: {report['iterations']} iters, "
          f"{report['finished_requests']} finished")
    for k, v in ss.items():
        print(f"  steady {k:12s} mean={v['mean_us']:9.1f}us "
              f"p99={v['p99_us']:9.1f}us (n={v['n']})")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
