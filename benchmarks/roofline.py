"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run artifacts (results/dryrun), runs the trip-count-corrected
HLO analyzer over each compiled module, and derives per-device:

  compute term    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16)
  memory term     = HLO_bytes / HBM_bw              (819 GB/s)
  collective term = collective_bytes / link_bw      (~50 GB/s/link ICI)

(the compiled module is the per-device SPMD program, so no further /chips).
Also reports MODEL_FLOPS = 6*N(_active)*tokens (analytic) and the
MODEL_FLOPS/HLO_FLOPs usefulness ratio, the dominant term, and a one-line
"what would move it" note.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dryrun results/dryrun]
       [--out results/roofline.json] [--md results/roofline.md]
"""
from __future__ import annotations

import argparse
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(arch: str, shape: str) -> float:
    """Analytic useful FLOPs for the whole cell (all devices)."""
    from repro.configs import SHAPES, get_config
    cfg = get_config(arch)
    sh = SHAPES[shape]
    pc = cfg.param_counts()
    n_attn = sum(1 for k in cfg.layer_kinds() if k["mixer"] == "attn")
    hq, hd = cfg.num_heads, cfg.head_dim_
    if cfg.is_mla:
        dk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        dv = cfg.v_head_dim
    else:
        dk = dv = hd
    V, D = cfg.padded_vocab, cfg.d_model

    if sh.kind == "train":
        T = sh.global_batch * sh.seq_len
        body = 6 * pc["body_active"] * T
        attn = 3 * 2 * T * sh.seq_len * hq * (dk + dv) * 0.5 * n_attn
        head = 3 * 2 * T * D * V * (2 if not cfg.tie_embeddings else 1) / 2
        if cfg.is_encoder_decoder:
            attn *= 2  # enc self + dec cross (coarse)
        return body + attn + head
    if sh.kind == "prefill":
        T = sh.global_batch * sh.seq_len
        body = 2 * pc["body_active"] * T
        attn = 2 * T * sh.seq_len * hq * (dk + dv) * 0.5 * n_attn
        head = 2 * sh.global_batch * D * V
        return body + attn + head
    # decode: one token per request against a seq_len KV
    T = sh.global_batch
    body = 2 * pc["body_active"] * T
    attn = 2 * T * sh.seq_len * hq * (dk + dv) * n_attn
    head = 2 * T * D * V
    return body + attn + head


def bound_note(dom: str, kind: str) -> str:
    if dom == "memory" and kind == "decode":
        return ("KV/weight streaming bound: raise per-instance batch or "
                "quantise KV (fp8) to cut sweep bytes")
    if dom == "memory":
        return "HBM bound: fuse/remat to cut activation traffic"
    if dom == "collective":
        return ("ICI bound: cut rotation rounds (rounds_used), widen per-hop "
                "payload, or overlap routing with local attention")
    return "MXU bound: raise arithmetic intensity (batch) or cut remat recompute"


def analyze_cell(rec: dict, dryrun_dir: str) -> dict | None:
    from . import hlo_analysis
    if not rec.get("ok") or "hlo" not in rec:
        return None
    res = hlo_analysis.analyze_file(os.path.join(dryrun_dir, rec["hlo"]))
    chips = CHIPS[rec["mesh"]]
    t_c = res["flops"] / PEAK_FLOPS
    t_m = res["bytes"] / HBM_BW
    t_x = res["collective_bytes"] / LINK_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec.get("kind", "?"),
        "hlo_flops_per_dev": res["flops"],
        "hlo_bytes_per_dev": res["bytes"],
        "coll_bytes_per_dev": res["collective_bytes"],
        "coll_by_kind": {k: round(v) for k, v in
                         res["collective_by_kind"].items()},
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom,
        "model_flops_total": mf,
        "useful_ratio": mf / chips / max(res["flops"], 1.0),
        "bytes_per_device_hbm": rec.get("bytes_per_device", 0),
        "note": bound_note(dom, rec.get("kind", "?")),
    }
    return out


def fmt_us(x: float) -> str:
    return f"{x*1e6:10.1f}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--md", default="results/roofline.md")
    ap.add_argument("--mesh", default="16x16",
                    help="mesh for the table (single-pod per the brief)")
    args = ap.parse_args()

    recs = json.load(open(os.path.join(args.dryrun, "dryrun.json")))
    rows = []
    for rec in recs:
        if rec["mesh"] != args.mesh:
            continue
        row = analyze_cell(rec, args.dryrun)
        if row:
            rows.append(row)
            print(f"{row['arch']:24s} {row['shape']:12s} "
                  f"C={row['t_compute_s']*1e6:9.1f}us "
                  f"M={row['t_memory_s']*1e6:9.1f}us "
                  f"X={row['t_collective_s']*1e6:9.1f}us "
                  f"dom={row['dominant']:10s} "
                  f"useful={row['useful_ratio']:.2f}", flush=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)

    with open(args.md, "w") as f:
        f.write("| arch | shape | kind | compute | memory | collective | "
                "dominant | MODEL/HLO | HBM GiB/dev | note |\n")
        f.write("|---|---|---|---|---|---|---|---|---|---|\n")
        for r in rows:
            f.write(
                f"| {r['arch']} | {r['shape']} | {r['kind']} "
                f"| {r['t_compute_s']*1e6:.0f}us | {r['t_memory_s']*1e6:.0f}us "
                f"| {r['t_collective_s']*1e6:.0f}us | **{r['dominant']}** "
                f"| {r['useful_ratio']:.2f} "
                f"| {r['bytes_per_device_hbm']/2**30:.2f} | {r['note']} |\n")
    print(f"\nwrote {args.out} and {args.md} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
