"""Benchmark regression gate: smoke-run JSON vs committed baselines.

CI runs the smoke benchmarks (``decode_step.py --smoke`` /
``escalation.py --smoke``) and then this script, which compares the p50 of
each gated metric against the committed baseline under
``benchmarks/baselines/`` and FAILS (exit 1) when any metric regressed by
more than the tolerance (default 25%, ``--tol`` / ``$BENCH_REGRESSION_TOL``).

Updating a baseline is an EXPLICIT act: run with ``--update`` locally and
commit the refreshed ``benchmarks/baselines/*.json`` — the gate never
rewrites baselines on its own, so a perf regression cannot silently ratchet
the baseline upward.

  PYTHONPATH=src python benchmarks/check_regression.py \\
      [--decode BENCH_decode_step.json] [--escalation BENCH_escalation.json] \\
      [--tol 0.25] [--metric-tol KEY=TOL ...] [--allow-full] [--update]

Gated metrics (host-overhead-dominated p50s, the most machine-stable of the
smoke numbers — full-step / device-completion times are deliberately NOT
gated: they are compute-dominated and too noisy on shared runners):
  decode_step:  steady_state.lower_us.p50, steady_state.tables_us.p50
  escalation:   dispatch.p50_us per pages_moved cell, plus the relax cells
                (reshard-back latency per pages reclaimed)

Tolerances are per-metric: ``--tol`` is the global default; ``--metric-tol
PREFIX=TOL`` (repeatable) overrides it absolutely for every metric whose
``file:key`` name starts with PREFIX (longest prefix wins).  Built-in
EXTRAS (``DEFAULT_METRIC_TOL_EXTRA``) are ADDED to the global tolerance
for known-noisy metrics.  ``--allow-full`` lets the
NIGHTLY job compare a full (non ``--smoke``) run against the committed
smoke baselines — the baseline's cells are a subset of the full sweep's.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

BASE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baselines")
DEFAULTS = {
    "decode": ("BENCH_decode_step.json", "BENCH_decode_step.smoke.json"),
    "escalation": ("BENCH_escalation.json", "BENCH_escalation.smoke.json"),
    "slo_sweep": ("BENCH_slo_sweep.json", "BENCH_slo_sweep.smoke.json"),
    "prefix_cache": ("BENCH_prefix_cache.json",
                     "BENCH_prefix_cache.smoke.json"),
    "disagg": ("BENCH_disagg_sweep.json", "BENCH_disagg_sweep.smoke.json"),
}

# metrics where BIGGER is better (sustainable rate, attainment, goodput):
# the regression ratio inverts (baseline/current), so a DROP fails the gate
# and an improvement never does.  Prefix match on "file:key".
HIGHER_IS_BETTER_PREFIXES = ("slo_sweep:", "prefix_cache:hit_rate",
                             "prefix_cache:saved", "disagg:",
                             "escalation:quant.fp8.bytes_ratio",
                             "escalation:quant.int8.bytes_ratio")

# built-in per-metric EXTRA tolerance (prefix of "file:key" -> added ON
# TOP of the global --tol, so a looser global gate — the nightly's
# --tol 0.5 — stays at least that loose everywhere); CLI --metric-tol
# entries are ABSOLUTE overrides and win over these
DEFAULT_METRIC_TOL_EXTRA = {
    # the relax cells run the host-side relax planner (WaterFill +
    # page-table bookkeeping) inside every rep — noisier than the pure
    # coordinate-upload escalation cells
    "escalation:relax.": 0.15,
}


def _longest_prefix(full: str, table: dict):
    best, best_len = None, -1
    for prefix, t in table.items():
        if full.startswith(prefix) and len(prefix) > best_len:
            best, best_len = t, len(prefix)
    return best


def tol_for(name: str, key: str, default: float,
            overrides: dict) -> float:
    """Absolute CLI override (longest prefix) wins; else the global default
    plus any built-in per-metric extra."""
    full = f"{name}:{key}"
    absolute = _longest_prefix(full, overrides)
    if absolute is not None:
        return absolute
    extra = _longest_prefix(full, DEFAULT_METRIC_TOL_EXTRA)
    return default + (extra or 0.0)


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def decode_metrics(rep: dict) -> dict:
    ss = rep.get("steady_state", {})
    out = {}
    for k in ("lower_us", "tables_us"):
        if k in ss and ss[k].get("n"):
            out[f"steady.{k}.p50"] = float(ss[k]["p50_us"])
    return out


def escalation_metrics(rep: dict) -> dict:
    out = {f"pages{c['pages_moved']}.dispatch.p50":
           float(c["dispatch"]["p50_us"]) for c in rep.get("cells", [])}
    # relax smoke metric: reshard-back (consolidation) latency vs pages
    # reclaimed, through the real scheduler relax planner
    out.update({f"relax.pages{c['pages_reclaimed']}.dispatch.p50":
                float(c["dispatch"]["p50_us"])
                for c in rep.get("relax_cells", [])})
    # quantized-KV payload metrics are ANALYTIC (model geometry x dtype
    # width + LatencyModel), hence deterministic: the default tolerance
    # pins them exactly in practice.  bytes_ratio (bf16/quant payload) is
    # higher-is-better — a drop means the quantized pools stopped saving
    # bandwidth (see HIGHER_IS_BETTER_PREFIXES).
    for c in rep.get("cells", [])[:1]:
        if "bytes_per_token" in c:
            out["bytes_per_token"] = float(c["bytes_per_token"])
    for c in rep.get("quant_cells", []):
        q = f"quant.{c['kv_dtype']}"
        out[f"{q}.bytes_per_token"] = float(c["bytes_per_token"])
        out[f"{q}.bytes_ratio"] = float(c["bytes_ratio"])
        out[f"{q}.pages{c['pages_moved']}.modeled_reshard_us"] = \
            float(c["modeled_reshard_us"])
        out[f"{q}.pages{c['pages_moved']}.dispatch.p50"] = \
            float(c["dispatch"]["p50_us"])
    return out


def slo_metrics(rep: dict) -> dict:
    """Gate the sweep's HEADLINE shape, not its latency noise: per
    (tier, mix, policy) the max sustainable rate and the attainment at
    that knee.  Both are higher-is-better (see HIGHER_IS_BETTER_PREFIXES);
    a drop in either means the closed loop lost serving capacity."""
    out = {}
    for tier, mixes in rep.get("curves", {}).items():
        for mix, policies in mixes.items():
            for pol, row in policies.items():
                out[f"{tier}.{mix}.{pol}.max_rate"] = float(row["max_rate"])
                knee = row.get("knee_attainment")
                if knee is not None:
                    out[f"{tier}.{mix}.{pol}.knee_attainment"] = float(knee)
    return out


def prefix_metrics(rep: dict) -> dict:
    """Gate the share-ratio sweep's headline shape: per share level the
    cache hit rate (higher-is-better) and the novel prompt tokens actually
    prefilled (lower-is-better), plus the prefill fraction the cache saves
    vs the cache-off control at top share (higher-is-better).  The sim is
    deterministic, so these are exact — a drift means behavior changed."""
    out = {}
    for c in rep.get("cells", []):
        tag = f"f{int(round(c['frac'] * 100)):02d}"
        out[f"hit_rate.{tag}"] = float(c["hit_rate"])
        out[f"novel_tokens.{tag}"] = float(c["novel_prompt_tokens"])
    ctrl = rep.get("control")
    if ctrl and rep.get("cells") and ctrl["prefill_time_s"] > 0:
        top = rep["cells"][-1]
        out["saved_prefill_frac"] = 1.0 - (top["prefill_time_s"]
                                           / ctrl["prefill_time_s"])
    return out


def disagg_metrics(rep: dict) -> dict:
    """Gate the cell-ratio sweep's headline shape: per mode (colocated /
    cellsN) the short-tier TTFT knee rate and the attainment at every
    swept rate.  All higher-is-better — the sim is deterministic, so a
    drop means the disaggregated handoff path lost serving capacity (a
    knee that merely MOVES UP when the nightly full grid extends the
    rate range never fails the subset comparison)."""
    out = {}
    for mode, row in rep.get("curves", {}).items():
        out[f"{mode}.knee"] = float(row["knee_rate"])
        for r in row.get("rows", []):
            out[f"{mode}.att_r{r['rate']}"] = float(r["ttft_attainment"])
    return out


def compare(name: str, cur: dict, base: dict, tol: float,
            metric_tol: dict | None = None) -> list[str]:
    failures = []
    metric_tol = metric_tol or {}
    for k, b in sorted(base.items()):
        c = cur.get(k)
        if c is None:
            failures.append(f"{name}:{k}: metric missing from current run")
            continue
        t = tol_for(name, k, tol, metric_tol)
        hib = any(f"{name}:{k}".startswith(p)
                  for p in HIGHER_IS_BETTER_PREFIXES)
        if hib:
            # a higher-is-better metric regresses when it FALLS: the
            # ratio inverts so the same ">1+tol fails" rule applies
            ratio = b / c if c > 0 else (float("inf") if b > 0 else 1.0)
            unit = ""
        else:
            ratio = c / b if b > 0 else float("inf")
            unit = "us"
        verdict = "FAIL" if ratio > 1.0 + t else "ok"
        print(f"  {name}:{k:30s} base={b:10.1f}{unit} cur={c:10.1f}{unit} "
              f"ratio={ratio:5.2f} tol={t:4.2f}  {verdict}")
        if verdict == "FAIL":
            failures.append(
                f"{name}:{k}: {c:.1f}{unit} vs baseline {b:.1f}{unit} "
                f"({'-' if hib else '+'}{abs(ratio - 1) * 100:.0f}% "
                f"> {t * 100:.0f}%)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--decode", default=DEFAULTS["decode"][0])
    ap.add_argument("--escalation", default=DEFAULTS["escalation"][0])
    ap.add_argument("--slo-sweep", dest="slo_sweep",
                    default=DEFAULTS["slo_sweep"][0])
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    default=DEFAULTS["prefix_cache"][0])
    ap.add_argument("--disagg", default=DEFAULTS["disagg"][0])
    ap.add_argument("--tol", type=float, default=float(
        os.environ.get("BENCH_REGRESSION_TOL", "0.25")))
    ap.add_argument("--metric-tol", action="append", default=[],
                    metavar="PREFIX=TOL",
                    help="per-metric tolerance override (prefix of "
                         "'file:key'; repeatable; longest prefix wins)")
    ap.add_argument("--allow-full", action="store_true",
                    help="permit a full (non --smoke) current run against "
                         "the committed smoke baselines (nightly job)")
    ap.add_argument("--update", action="store_true",
                    help="copy the current smoke JSONs over the committed "
                         "baselines (then commit them explicitly)")
    args = ap.parse_args()
    metric_tol = {}
    for spec in args.metric_tol:
        prefix, _, t = spec.partition("=")
        if not t:
            ap.error(f"--metric-tol wants PREFIX=TOL, got {spec!r}")
        metric_tol[prefix] = float(t)

    if args.update:
        os.makedirs(BASE_DIR, exist_ok=True)
        for key, (cur_path, base_name) in DEFAULTS.items():
            cur = getattr(args, key)
            shutil.copy(cur, os.path.join(BASE_DIR, base_name))
            print(f"baseline updated: {os.path.join(BASE_DIR, base_name)}")
        return 0

    failures = []
    for key, extract in (("decode", decode_metrics),
                         ("escalation", escalation_metrics),
                         ("slo_sweep", slo_metrics),
                         ("prefix_cache", prefix_metrics),
                         ("disagg", disagg_metrics)):
        cur_path = getattr(args, key)
        base_path = os.path.join(BASE_DIR, DEFAULTS[key][1])
        if not os.path.exists(base_path):
            print(f"{key}: no committed baseline at {base_path} — skipping")
            continue
        cur, base = _load(cur_path), _load(base_path)
        if not base.get("smoke", False):
            print(f"{key}: committed baseline must be a SMOKE run "
                  f"(base smoke={base.get('smoke')})")
            return 2
        if not cur.get("smoke", False) and not args.allow_full:
            print(f"{key}: gate compares SMOKE runs only "
                  f"(cur smoke={cur.get('smoke')}; pass --allow-full for "
                  f"the nightly full-sweep comparison)")
            return 2
        failures += compare(key, extract(cur), extract(base), args.tol,
                            metric_tol)

    if failures:
        print("\nbenchmark regression gate FAILED:")
        for f in failures:
            print(f"  {f}")
        print("\n(if this slowdown is intended, refresh the baseline with "
              "`python benchmarks/check_regression.py --update` and commit "
              "benchmarks/baselines/ explicitly)")
        return 1
    print("\nbenchmark regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
