"""One benchmark per paper table/figure (DESIGN.md §5 experiment index).

Each ``bench_*`` function returns a Rows accumulator; ``run.py`` emits the
combined ``name,us_per_call,derived`` CSV.  The control plane in every
simulation is the REAL NanoCP code; data-plane latencies come from the
roofline-calibrated model (DESIGN.md §3).
"""
from __future__ import annotations

import time

import numpy as np

from repro.serving import metrics
from repro.serving.workload import (DATASETS, OPENROUTER, make_workload)

from .common import BUCKETS, CFG, LM, N_INST, PER_NODE, Rows, make_scheduler, simulate


# --------------------------------------------------------------------------- #
def bench_table1_workloads() -> Rows:
    """Table 1: dataset length-interval shares of the synthetic traces."""
    r = Rows()
    for kind in ("sharegpt4o", "github_issue", "openrouter"):
        wl = make_workload(kind, rate=300, duration=20, seed=0)
        for interval, share in wl.interval_shares().items():
            r.add(f"table1/{kind}/{interval}", 0.0, round(share, 4))
    return r


def bench_fig3_micro() -> Rows:
    """Fig. 3: attention latency vs KV size; all-to-all latency vs batch."""
    r = Rows()
    for kv in (10_000, 50_000, 100_000, 300_000, 600_000, 1_000_000):
        r.add(f"fig3a/attention_kv={kv}", LM.attention_time(kv, 64) * 1e6,
              "per-layer")
    for b in (16, 32, 64, 128, 256, 512):
        r.add(f"fig3b/a2a_batch={b}", LM.a2a_time(b) * 1e6, "dispatch-or-combine")
    return r


def bench_fig5_imbalance() -> Rows:
    """Fig. 5: LeastBatch / LeastCache pathologies under load."""
    r = Rows()
    for name in ("least_batch", "least_cache"):
        _, _, res = simulate(name, rate=200)
        attn = np.stack(res.attn_lat_series)
        a2a = np.stack(res.a2a_lat_series)
        r.add(f"fig5/{name}/attn_max", attn.max(1).mean() * 1e6,
              f"mean={attn.mean()*1e6:.1f}us")
        r.add(f"fig5/{name}/a2a_max", a2a.max(1).mean() * 1e6,
              f"headroom={100*(1-a2a.mean()/max(a2a.max(1).mean(),1e-12)):.1f}%")
        # Fig 5c: head-of-line gap — free memory while a request queues
        free = np.asarray(res.free_mem_series, float)
        hol = np.asarray(res.hol_demand_series, float)
        blocked = hol > 0
        r.add(f"fig5c/{name}/free_frames_while_blocked", 0.0,
              round(float(free[blocked].mean()) if blocked.any() else 0.0, 1))
    return r


def bench_fig6_helix() -> Rows:
    """Fig. 6: uniform-CP per-layer attention breakdown vs (seq x batch)."""
    r = Rows()
    for seq, batch in ((8_192, 128), (32_768, 32), (131_072, 8), (524_288, 2)):
        total_kv = seq * batch
        for cp in (1, 2, 4, 8):
            attn = LM.attention_time(total_kv / cp, batch * cp)
            comm = 2 * LM.dense_cp_route_time(cp, batch * cp)
            r.add(f"fig6/seq{seq}xb{batch}/cp{cp}", (attn + comm) * 1e6,
                  f"comm_share={comm/(attn+comm):.2f}")
    return r


def bench_fig12_e2e() -> Rows:
    """Fig. 12: max sustainable request rate @ >=99% of TPOT<=50ms (headline)."""
    r = Rows()
    rates = (50, 100, 150, 200, 250, 300, 400, 500, 650, 800, 1000, 1300)
    best = {}
    qt = metrics.tpot_with_queueing          # the figure's normalization
    for ratio in (0.01, 0.05):
        for name in ("nanocp", "least_batch", "least_cache", "cp4", "cp8"):
            # full-scan knee: attainment is not monotone in offered rate, so
            # the old first-miss early-break could under-report the knee —
            # max_sustainable_rate walks the whole grid and counts every
            # submitted request (unserved = violation) in the denominator
            def run_at(rate, _name=name, _ratio=ratio):
                _, _, res = simulate(_name, rate=rate, long_ratio=_ratio,
                                     duration=8.0)
                return res.finished, res.submitted
            sustained, stats = metrics.max_sustainable_rate(
                run_at, rates, slo=0.05, target=0.99, tpot_fn=qt)
            best[(ratio, name)] = sustained
            r.add(f"fig12/mixed{int(ratio*100)}%/{name}/max_rate",
                  stats[sustained]["mean_tpot"] * 1e6 if sustained else 0.0,
                  sustained)
        base = max(best[(ratio, n)] for n in
                   ("least_batch", "least_cache", "cp4", "cp8"))
        r.add(f"fig12/mixed{int(ratio*100)}%/speedup_vs_best_baseline", 0.0,
              round(best[(ratio, 'nanocp')] / max(base, 1), 2))
    return r


def bench_fig13_micro() -> Rows:
    """Fig. 13: slowest-instance latency breakdown, 1/3/5/7 long reqs/node."""
    from repro.core.state import ClusterState, Request
    r = Rows()
    for n_long in (1, 3, 5, 7):
        for name in ("nanocp", "least_batch", "cp8"):
            from repro.serving.simulator import ClusterSimulator
            sim = ClusterSimulator(CFG, make_scheduler(name),
                                   num_instances=N_INST,
                                   instances_per_node=PER_NODE,
                                   kv_capacity_tokens=1_000_000)
            cl = sim.cluster
            rid = 0
            for i in range(N_INST * 8):          # 64 short per GPU-ish scale
                cl.enqueue(Request(rid=rid, prompt_len=2048,
                                   max_new_tokens=8))
                rid += 1
            for node in range(N_INST // PER_NODE):
                for _ in range(n_long):
                    cl.enqueue(Request(rid=rid, prompt_len=512_000,
                                       max_new_tokens=8))
                    rid += 1
            plan = sim.scheduler.schedule(cl)
            t, ph, _, _ = sim._iteration_time(plan)
            r.add(f"fig13/long{n_long}/{name}/layer_total",
                  ph.layer_total * 1e6,
                  f"attn={ph.attention*1e6:.1f};cp={ph.cp_comm*1e6:.1f};"
                  f"a2a={ph.dispatch_combine*1e6:.1f}")
    return r


def bench_fig14_balance() -> Rows:
    """Fig. 14: KV/batch imbalance + HoL blocking."""
    r = Rows()
    for name in ("nanocp", "least_batch", "least_cache"):
        _, _, res = simulate(name, rate=250, long_ratio=0.05)
        kv = np.mean([metrics.imbalance_pct(k) for k in res.kv_series])
        bb = np.mean([metrics.imbalance_pct(b) for b in res.batch_series])
        free = np.asarray(res.free_mem_series, float)
        hol = np.asarray(res.hol_demand_series, float)
        blocked_frac = float((hol > 0).mean())
        r.add(f"fig14/{name}/kv_imbalance_pct", 0.0, round(float(kv), 1))
        r.add(f"fig14/{name}/batch_imbalance_pct", 0.0, round(float(bb), 1))
        r.add(f"fig14/{name}/hol_blocked_iter_frac", 0.0,
              round(blocked_frac, 3))
    return r


def bench_fig15_layer() -> Rows:
    """Fig. 15: per-layer attention max vs median across strategies."""
    r = Rows()
    for kind, ratio in (("sharegpt4o", 0.0), ("mixed", 0.01), ("mixed", 0.05)):
        for name in ("nanocp", "cp8", "least_batch", "least_cache"):
            _, _, res = simulate(name, rate=150, long_ratio=ratio, kind=kind)
            attn = np.stack(res.attn_lat_series)
            mx = attn.max(1).mean() * 1e6
            med = np.median(attn, axis=1).mean() * 1e6
            a2a = np.stack(res.a2a_lat_series).max(1).mean() * 1e6
            label = kind if ratio == 0 else f"mixed{int(ratio*100)}%"
            r.add(f"fig15/{label}/{name}/attn_max", mx,
                  f"median={med:.1f};gap={mx/max(med,1e-9):.2f}x;a2a={a2a:.1f}")
    return r


def bench_fig16_overhead() -> Rows:
    """Fig. 16: REAL control-plane wall time vs modeled iteration time."""
    from repro.core.routing import lower_plan
    from repro.core.state import ClusterState, Request
    from repro.serving.simulator import ClusterSimulator
    r = Rows()
    for batch_per_inst in (32, 64, 128, 256):
        sim = ClusterSimulator(CFG, make_scheduler("nanocp"),
                               num_instances=N_INST,
                               instances_per_node=PER_NODE,
                               kv_capacity_tokens=1_000_000)
        cl = sim.cluster
        for rid in range(batch_per_inst * N_INST):
            cl.enqueue(Request(rid=rid, prompt_len=2048, max_new_tokens=4))
        sim.scheduler.schedule(cl)          # admission (one-off)
        t0 = time.perf_counter()
        plan = sim.scheduler.schedule(cl)    # steady-state iteration
        t_sched = time.perf_counter() - t0
        t0 = time.perf_counter()
        lower_plan(cl, plan, next_tokens={})
        t_lower = time.perf_counter() - t0
        t_iter, _, _, _ = sim._iteration_time(plan)
        pct = 100 * (t_sched + t_lower) / max(t_iter, 1e-9)
        r.add(f"fig16/batch{batch_per_inst}/schedule", t_sched * 1e6,
              f"lower={t_lower*1e6:.0f}us;pct_of_iter={pct:.2f}%")
    return r


def bench_fig17_backend() -> Rows:
    """Fig. 17: routed backend vs dense NCCL-style collectives."""
    from repro.core import comm
    r = Rows()
    q_bytes = LM.q_row_bytes
    for batch in (8, 32, 128):
        for s_rows in (1, 4, 8):
            routed = comm.routed_bytes(PER_NODE - 1, s_rows, q_bytes)
            dense = comm.dense_bytes(N_INST, batch, q_bytes)
            t_r = LM.cp_route_time(PER_NODE - 1, s_rows)
            t_d = LM.dense_cp_route_time(N_INST, batch)
            r.add(f"fig17/b{batch}_s{s_rows}/routed", t_r * 1e6,
                  f"bytes={routed}")
            r.add(f"fig17/b{batch}_s{s_rows}/dense", t_d * 1e6,
                  f"bytes={dense};saving={100*(1-routed/max(dense,1)):.1f}%")
    return r


def bench_fig18_cpmix() -> Rows:
    """Fig. 18: runtime CP-degree distribution (DCP cost at runtime)."""
    r = Rows()
    _, _, res = simulate("nanocp", rate=150, long_ratio=0.01)
    total = sum(res.cp_degree_hist.values())
    for deg in sorted(res.cp_degree_hist):
        share = res.cp_degree_hist[deg] / max(total, 1)
        r.add(f"fig18/cp{deg}", 0.0, round(share, 4))
    multi = sum(v for k, v in res.cp_degree_hist.items() if k > 1)
    r.add("fig18/cross_instance_share", 0.0, round(multi / max(total, 1), 4))
    return r


def bench_table2_aot() -> Rows:
    """Table 2: AOT executable family size + buffer-pool bytes."""
    from repro.core.bucketing import ShapeBuckets
    r = Rows()
    sb = ShapeBuckets(m_buckets=(1, 2, 4, 8, 16, 32), s_buckets=(0, 1, 2, 4, 8),
                      window=PER_NODE)
    fam = sb.family()
    # per-bucket routing+payload buffer bytes (Alg. 2 pools), DSv3 dims
    q_bytes = LM.q_row_bytes
    pool = 0
    for (m, s, n) in fam:
        pool += (PER_NODE - 1) * s * q_bytes * 2 + n * q_bytes
    r.add("table2/nanocp/num_graphs", 0.0, len(fam))
    r.add("table2/nanocp/pool_MiB", 0.0, round(pool / 2**20, 2))
    uniform = [(m, 0, m) for m in sb.m_buckets for _ in range(12)]
    r.add("table2/uniform_cp_equiv/num_graphs", 0.0, len(uniform))
    return r
