# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: every paper table/figure (DESIGN.md §5).

  PYTHONPATH=src python -m benchmarks.run [--only fig12,fig14,...]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings (e.g. fig12,table1)")
    args = ap.parse_args()

    from . import paper_figures as pf
    benches = [
        ("table1", pf.bench_table1_workloads),
        ("fig3", pf.bench_fig3_micro),
        ("fig5", pf.bench_fig5_imbalance),
        ("fig6", pf.bench_fig6_helix),
        ("fig12", pf.bench_fig12_e2e),
        ("fig13", pf.bench_fig13_micro),
        ("fig14", pf.bench_fig14_balance),
        ("fig15", pf.bench_fig15_layer),
        ("fig16", pf.bench_fig16_overhead),
        ("fig17", pf.bench_fig17_backend),
        ("fig18", pf.bench_fig18_cpmix),
        ("table2", pf.bench_table2_aot),
    ]
    only = args.only.split(",") if args.only else None
    print("name,us_per_call,derived")
    for name, fn in benches:
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        rows = fn()
        rows.emit()
        print(f"# {name}: ok ({time.time()-t0:.1f}s)", file=sys.stderr)


if __name__ == "__main__":
    main()
