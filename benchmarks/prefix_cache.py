"""Global prefix-cache sweep: prefill saved and TTFT vs trace share ratio.

Sweeps the workload's shared-prefix knob (``shared_prefix_frac`` at a fixed
group count) on the paper-scale simulator (deepseek-v3 analytic data plane,
real control plane) with the global CoW prefix cache ON and prefill charged
into sim time at admission (``charge_prefill=True``) — so a cache hit shows
up exactly where it matters: fewer novel prompt tokens prefilled, lower
TTFT.  The rng stream is identical across share levels (same seed, same
draw sequence), so the ONLY thing that varies is how much of each prompt
carries a shared key chain: every curve is an apples-to-apples ablation.

Emits ``BENCH_prefix_cache.json`` (or ``--out``).  ``--smoke`` shrinks the
grid to the CI cells gated by ``check_regression.py``; the full sweep runs
nightly.  Exits 1 unless, as share grows:

  * prefix hit tokens rise monotonically,
  * novel (actually prefilled) prompt tokens fall monotonically,
  * mean TTFT falls monotonically,

and a cache-OFF control at the top share shows the cache saving prefill
seconds without changing the request outcomes — the headline is asserted,
not eyeballed.

  PYTHONPATH=src python benchmarks/prefix_cache.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

GROUPS = 4                   # shared-prefix template pool (system prompts)
FRACS_FULL = (0.0, 0.25, 0.5, 0.75, 0.9)
FRACS_SMOKE = (0.0, 0.5, 0.9)
RATE_FULL = 120.0
RATE_SMOKE = 60.0
DURATION = 2.0
HORIZON = 30.0
SEED = 0
PAGE = 64                    # workload key granularity == sim page size
# monotonicity slack: the sweep is deterministic, but TTFT folds queueing
# in — allow a hair of float noise, never a real reversal
REL_EPS = 1e-6


def run_cell(frac: float, rate: float, *, cache: bool) -> dict:
    from repro.serving import metrics
    from repro.serving.simulator import ClusterSimulator
    from repro.serving.workload import make_workload

    sys.path.insert(0, __import__("os").path.dirname(
        __import__("os").path.abspath(__file__)))
    from common import CFG, N_INST, PER_NODE, make_scheduler

    wl = make_workload("sharegpt4o", rate=rate, duration=DURATION, seed=SEED,
                       shared_prefix_groups=GROUPS, shared_prefix_frac=frac,
                       page_size=PAGE)
    sim = ClusterSimulator(CFG, make_scheduler("nanocp"), num_instances=N_INST,
                           instances_per_node=PER_NODE,
                           kv_capacity_tokens=1_000_000, page_size=PAGE,
                           multi_step=4, prefix_cache=cache,
                           charge_prefill=True)
    res = sim.run(wl, horizon=HORIZON)
    fin = res.finished
    return {
        "frac": frac,
        "rate": rate,
        "cache": cache,
        "trace_share": wl.prefix_share(PAGE),
        "submitted": res.submitted,
        "finished": len(fin),
        "prompt_tokens": res.prompt_tokens,
        "prefix_hit_tokens": res.prefix_hit_tokens,
        "novel_prompt_tokens": res.prompt_tokens - res.prefix_hit_tokens,
        "hit_rate": metrics.prefix_hit_rate(res),
        "prefill_time_s": res.prefill_time,
        "mean_ttft_s": metrics.mean_ttft(fin),
        "p99_ttft_s": metrics.p99_ttft(fin),
        "cow_splits": res.cow_splits,
        "cow_tokens": res.cow_tokens,
        "copy_tokens": res.copy_tokens,
        "evicted_prefix_frames": res.evicted_prefix_frames,
        "oom_finishes": res.oom_finishes,
        "sim_time_s": res.sim_time,
    }


def check_headline(cells: list[dict], control: dict) -> list[str]:
    """The claims the gate asserts: hits rise, novel prefill and TTFT fall
    monotonically with share; the cache-off control at top share pays more
    prefill and finishes the same request set."""
    failures = []
    for a, b in zip(cells, cells[1:]):
        pair = f"frac {a['frac']} -> {b['frac']}"
        if b["prefix_hit_tokens"] < a["prefix_hit_tokens"]:
            failures.append(f"{pair}: hit tokens fell "
                            f"({a['prefix_hit_tokens']} -> "
                            f"{b['prefix_hit_tokens']})")
        if b["novel_prompt_tokens"] > a["novel_prompt_tokens"]:
            failures.append(f"{pair}: novel prefill tokens rose "
                            f"({a['novel_prompt_tokens']} -> "
                            f"{b['novel_prompt_tokens']})")
        if b["mean_ttft_s"] > a["mean_ttft_s"] * (1 + REL_EPS):
            failures.append(f"{pair}: mean TTFT rose ({a['mean_ttft_s']:.4f}s "
                            f"-> {b['mean_ttft_s']:.4f}s)")
    top = cells[-1]
    if top["prefix_hit_tokens"] <= 0:
        failures.append("top share cell never hit the cache")
    if not control["prefill_time_s"] > top["prefill_time_s"]:
        failures.append(
            f"cache-off control prefilled no more than cache-on "
            f"({control['prefill_time_s']:.3f}s vs "
            f"{top['prefill_time_s']:.3f}s)")
    if control["finished"] != top["finished"]:
        failures.append(
            f"cache changed the outcome set: {top['finished']} finished "
            f"with cache vs {control['finished']} without")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_prefix_cache.json")
    args = ap.parse_args()

    fracs = FRACS_SMOKE if args.smoke else FRACS_FULL
    rate = RATE_SMOKE if args.smoke else RATE_FULL
    cells = []
    for frac in fracs:
        t0 = time.time()
        c = run_cell(frac, rate, cache=True)
        cells.append(c)
        print(f"frac={frac:4.2f} share={c['trace_share']:.2f} "
              f"hit_rate={c['hit_rate']:.3f} "
              f"novel={c['novel_prompt_tokens']:>8d} "
              f"prefill={c['prefill_time_s']:7.3f}s "
              f"ttft={c['mean_ttft_s'] * 1e3:7.2f}ms "
              f"({time.time() - t0:.0f}s)", flush=True)
    control = run_cell(fracs[-1], rate, cache=False)
    print(f"ctrl frac={fracs[-1]:4.2f} cache=off "
          f"prefill={control['prefill_time_s']:7.3f}s "
          f"ttft={control['mean_ttft_s'] * 1e3:7.2f}ms", flush=True)

    rep = {"smoke": bool(args.smoke), "groups": GROUPS, "rate": rate,
           "duration": DURATION, "seed": SEED, "page_size": PAGE,
           "cells": cells, "control": control}
    with open(args.out, "w") as f:
        json.dump(rep, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")

    failures = check_headline(cells, control)
    if failures:
        print("\nprefix-cache headline FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("headline OK: hits rise, novel prefill and TTFT fall "
          "monotonically with share; cache-off control pays more prefill")
    return 0


if __name__ == "__main__":
    sys.exit(main())
