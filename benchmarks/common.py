"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.bucketing import derive_buckets
from repro.core.scheduler import (DualBalancedScheduler, LeastBatchScheduler,
                                  LeastCacheScheduler, UniformCPScheduler)
from repro.serving.latency_model import LatencyModel
from repro.serving.simulator import ClusterSimulator
from repro.serving.workload import make_workload

CFG = get_config("deepseek-v3")          # the paper's serving backbone
LM = LatencyModel(CFG)
BUCKETS = derive_buckets(LM)
N_INST, PER_NODE = 32, 8                 # paper: 32 DP instances, 8/node


def make_scheduler(name: str):
    return {
        "nanocp": lambda: DualBalancedScheduler(buckets=BUCKETS),
        "least_batch": LeastBatchScheduler,
        "least_cache": LeastCacheScheduler,
        "cp2": lambda: UniformCPScheduler(cp=2),
        "cp4": lambda: UniformCPScheduler(cp=4),
        "cp8": lambda: UniformCPScheduler(cp=8),
    }[name]()


def simulate(sched_name: str, *, rate: float, duration: float = 10.0,
             long_ratio: float = 0.05, seed: int = 0, horizon: float = 90.0,
             multi_step: int = 4, kind: str = "mixed"):
    wl = make_workload(kind, rate=rate, duration=duration,
                       long_ratio=long_ratio, seed=seed)
    sim = ClusterSimulator(CFG, make_scheduler(sched_name),
                           num_instances=N_INST, instances_per_node=PER_NODE,
                           kv_capacity_tokens=1_000_000,
                           multi_step=multi_step)
    res = sim.run(wl, horizon=horizon)
    return wl, sim, res


class Rows:
    """CSV accumulator: name,us_per_call,derived."""

    def __init__(self):
        self.rows = []

    def add(self, name: str, us: float, derived) -> None:
        self.rows.append((name, us, derived))

    def emit(self) -> None:
        for name, us, derived in self.rows:
            print(f"{name},{us:.3f},{derived}")
