"""KV re-shard micro-bench: live-migration latency + bytes vs pages moved.

Times ``migrate.KVReshard`` — the donated gather->scatter collective behind
mid-decode CP escalation — on a real multi-device serve state, sweeping the
number of KV pages moved between two instances.  Dispatch latency (host) and
completion latency (host + device, ``block_until_ready``) are reported per
page count; the compile of each padded token bucket is excluded by a warmup
call.  Each cell also records the ANALYTIC payload (``bytes_moved`` /
``bytes_per_token`` from the LatencyModel at the engine's ``--kv-dtype``)
and the modeled reshard time — deterministic numbers the regression gate
can hold tightly, unlike CPU wall clock.

``quant_cells`` re-runs the sweep on an fp8-pool engine (per-page scale
sidecars travel with the move) and reports its measured dispatch plus the
analytic bytes at both precisions: the bench itself exits nonzero unless
the quantized bytes/token is strictly below bf16 (the headline the
quantized pools exist for); ``check_regression.py`` then pins the ratio.

  PYTHONPATH=src python benchmarks/escalation.py [--smoke] [--out PATH]
      [--kv-dtype bf16|fp8|int8]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import statistics
import time


def _summ(xs):
    xs = sorted(xs)
    return {
        "mean_us": statistics.fmean(xs),
        "p50_us": xs[len(xs) // 2],
        "p99_us": xs[min(len(xs) - 1, int(len(xs) * 0.99))],
        "n": len(xs),
    }


def run_bench(smoke: bool = False, kv_dtype: str = "bf16") -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import compat
    from repro.configs import CONFIGS, reduced
    from repro.models import init_params
    from repro.serving.engine import NanoCPEngine
    from repro.serving.latency_model import LatencyModel

    cfg = reduced(CONFIGS["tinyllama-1.1b"], num_layers=2, vocab_size=256)
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          init_params(jax.random.PRNGKey(0), cfg))
    mesh = compat.make_mesh((2, 2), ("data", "model"))
    page = 16

    def build(kv: str) -> NanoCPEngine:
        return NanoCPEngine(cfg, params, mesh, num_instances=2,
                            instances_per_node=2, kv_capacity_tokens=4096,
                            page_size=page, kv_dtype=kv)

    eng = build(kv_dtype)
    # analytic payload accounting at the engine's KV precision: bytes are
    # deterministic (model geometry x dtype width), so the regression gate
    # holds them tightly where wall clock would be noise
    lm = LatencyModel(cfg, kv_dtype=kv_dtype)
    bpt = lm.kv_bytes_per_token * lm.num_attn_layers   # all attention layers

    def coords(pages: int, direction: int) -> tuple:
        """Move ``pages`` full pages instance 0 -> 1 (or back)."""
        t = pages * page
        j = np.arange(t)
        src = np.stack([np.full(t, direction), j // page, j % page])
        dst = np.stack([np.full(t, 1 - direction), j // page, j % page])
        return src.astype(np.int32), dst.astype(np.int32)

    def sweep(e: NanoCPEngine, page_counts, reps, model: LatencyModel,
              tag: str = "") -> list:
        out = []
        per_tok = model.kv_bytes_per_token * model.num_attn_layers
        for pages in page_counts:
            # warmup: compile this token bucket (excluded from timings)
            src, dst = coords(pages, 0)
            e.state = e._reshard(e.state, src, dst)
            jax.block_until_ready(jax.tree.leaves(e.state))
            disp, total = [], []
            for r in range(reps):
                src, dst = coords(pages, (r + 1) % 2)  # ping-pong directions
                t0 = time.perf_counter()
                e.state = e._reshard(e.state, src, dst)
                t1 = time.perf_counter()
                jax.block_until_ready(jax.tree.leaves(e.state))
                t2 = time.perf_counter()
                disp.append((t1 - t0) * 1e6)
                total.append((t2 - t0) * 1e6)
            t = pages * page
            out.append({"pages_moved": pages, "tokens_moved": t,
                        "bytes_moved": t * per_tok,
                        "bytes_per_token": per_tok,
                        "modeled_reshard_us":
                            model.kv_reshard_time(t) * 1e6,
                        "dispatch": _summ(disp), "complete": _summ(total)})
            print(f"{tag}pages={pages:4d} tokens={t:5d} "
                  f"bytes={t * per_tok / 1e3:8.1f}kB  "
                  f"dispatch p50 {out[-1]['dispatch']['p50_us']:8.1f}us  "
                  f"complete p50 {out[-1]['complete']['p50_us']:8.1f}us")
        return out

    page_counts = [1, 4, 16] if smoke else [1, 2, 4, 8, 16, 32, 64]
    reps = 3 if smoke else 10
    cells = sweep(eng, page_counts, reps, lm)

    # ---- relax cells: reshard-BACK latency vs pages reclaimed, through
    # the real scheduler relax planner (de-escalation of a 2-wide binding
    # whose growth has finished: member 1's whole shard consolidates onto
    # the MoE-binding shard).  Times the planner + the donated collective —
    # the cost `SimResult.relax_time` models.
    from repro.core.state import Request
    cl = eng.cluster
    sched = eng.scheduler
    relax_cells = []
    for pages in page_counts:
        t = pages * page
        disp, total = [], []
        for r in range(reps + 1):        # rep 0 warms the compile bucket
            rid = 10_000 + pages * 100 + r
            cl.page_table.allocate(rid, {0: page, 1: t})
            req = Request(rid=rid, prompt_len=page + t, max_new_tokens=0)
            req.kv_binding, req.moe_binding, req.node = [0, 1], 0, 0
            req.status = "running"
            cl.active[rid] = req
            t0 = time.perf_counter()
            recs = sched.relax(cl, force=True)
            assert recs and recs[0].tokens_moved == t, (pages, recs)
            eng.state = eng._reshard(eng.state, recs[0].src_coords,
                                     recs[0].dst_coords)
            t1 = time.perf_counter()
            jax.block_until_ready(jax.tree.leaves(eng.state))
            t2 = time.perf_counter()
            if r > 0:
                disp.append((t1 - t0) * 1e6)
                total.append((t2 - t0) * 1e6)
            cl.active.pop(rid)
            cl.page_table.free_request(rid)
        relax_cells.append({"pages_reclaimed": pages, "tokens_moved": t,
                            "bytes_moved": t * bpt,
                            "dispatch": _summ(disp),
                            "complete": _summ(total)})
        print(f"relax pages={pages:4d} tokens={t:5d}  "
              f"dispatch p50 {relax_cells[-1]['dispatch']['p50_us']:8.1f}us  "
              f"complete p50 {relax_cells[-1]['complete']['p50_us']:8.1f}us")

    # ---- quantized reshard cells: the same sweep on an fp8-pool engine
    # (KVReshard dequants with source page scales, requants at the
    # destination — the scale sidecars ride the same donated collective).
    # The cells carry the analytic bytes at BOTH precisions; the bench
    # self-gates on the headline (quantized payload strictly below bf16).
    qdt = kv_dtype if kv_dtype != "bf16" else "fp8"
    lm_q = LatencyModel(cfg, kv_dtype=qdt)
    lm_bf = LatencyModel(cfg, kv_dtype="bf16")
    q_eng = eng if kv_dtype == qdt else build(qdt)
    quant_cells = sweep(q_eng, page_counts, reps, lm_q, tag=f"{qdt} ")
    bf_bpt = lm_bf.kv_bytes_per_token * lm_bf.num_attn_layers
    for c in quant_cells:
        c["kv_dtype"] = qdt
        c["bf16_bytes_per_token"] = bf_bpt
        c["bytes_ratio"] = bf_bpt / c["bytes_per_token"]
        assert c["bytes_per_token"] < bf_bpt, (
            "quantized KV must move fewer bytes per token than bf16",
            qdt, c["bytes_per_token"], bf_bpt)
    print(f"quant[{qdt}]: bytes/token {quant_cells[0]['bytes_per_token']:.0f} "
          f"vs bf16 {bf_bpt:.0f} (x{quant_cells[0]['bytes_ratio']:.2f})")
    return {
        "bench": "kv_reshard_latency_vs_pages",
        "arch": "tinyllama-1.1b(reduced nl=2)",
        "topology": {"instances": 2, "tp": 2, "page_size": page},
        "kv_dtype": kv_dtype,
        "smoke": smoke,
        "cells": cells,
        "relax_cells": relax_cells,
        "quant_cells": quant_cells,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=("bf16", "fp8", "int8"),
                    help="KV pool precision of the MAIN sweep's engine "
                         "(the quant cells always run a quantized engine)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_escalation.json"))
    args = ap.parse_args()
    out = run_bench(smoke=args.smoke, kv_dtype=args.kv_dtype)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
