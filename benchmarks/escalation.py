"""KV re-shard micro-bench: live-migration latency vs pages moved.

Times ``migrate.KVReshard`` — the donated gather->scatter collective behind
mid-decode CP escalation — on a real multi-device serve state, sweeping the
number of KV pages moved between two instances.  Dispatch latency (host) and
completion latency (host + device, ``block_until_ready``) are reported per
page count; the compile of each padded token bucket is excluded by a warmup
call.  Emits ``BENCH_escalation.json`` at the repo root (or ``--out``).

  PYTHONPATH=src python benchmarks/escalation.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import statistics
import time


def _summ(xs):
    xs = sorted(xs)
    return {
        "mean_us": statistics.fmean(xs),
        "p50_us": xs[len(xs) // 2],
        "p99_us": xs[min(len(xs) - 1, int(len(xs) * 0.99))],
        "n": len(xs),
    }


def run_bench(smoke: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import compat
    from repro.configs import CONFIGS, reduced
    from repro.models import init_params
    from repro.serving.engine import NanoCPEngine

    cfg = reduced(CONFIGS["tinyllama-1.1b"], num_layers=2, vocab_size=256)
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          init_params(jax.random.PRNGKey(0), cfg))
    mesh = compat.make_mesh((2, 2), ("data", "model"))
    page = 16
    eng = NanoCPEngine(cfg, params, mesh, num_instances=2,
                       instances_per_node=2, kv_capacity_tokens=4096,
                       page_size=page)

    def coords(pages: int, direction: int) -> tuple:
        """Move ``pages`` full pages instance 0 -> 1 (or back)."""
        t = pages * page
        j = np.arange(t)
        src = np.stack([np.full(t, direction), j // page, j % page])
        dst = np.stack([np.full(t, 1 - direction), j // page, j % page])
        return src.astype(np.int32), dst.astype(np.int32)

    page_counts = [1, 4, 16] if smoke else [1, 2, 4, 8, 16, 32, 64]
    reps = 3 if smoke else 10
    cells = []
    for pages in page_counts:
        # warmup: compile this token bucket (excluded from timings)
        src, dst = coords(pages, 0)
        eng.state = eng._reshard(eng.state, src, dst)
        jax.block_until_ready(jax.tree.leaves(eng.state))
        disp, total = [], []
        for r in range(reps):
            src, dst = coords(pages, (r + 1) % 2)   # ping-pong directions
            t0 = time.perf_counter()
            eng.state = eng._reshard(eng.state, src, dst)
            t1 = time.perf_counter()
            jax.block_until_ready(jax.tree.leaves(eng.state))
            t2 = time.perf_counter()
            disp.append((t1 - t0) * 1e6)
            total.append((t2 - t0) * 1e6)
        cells.append({"pages_moved": pages, "tokens_moved": pages * page,
                      "dispatch": _summ(disp), "complete": _summ(total)})
        print(f"pages={pages:4d} tokens={pages * page:5d}  "
              f"dispatch p50 {cells[-1]['dispatch']['p50_us']:8.1f}us  "
              f"complete p50 {cells[-1]['complete']['p50_us']:8.1f}us")

    # ---- relax cells: reshard-BACK latency vs pages reclaimed, through
    # the real scheduler relax planner (de-escalation of a 2-wide binding
    # whose growth has finished: member 1's whole shard consolidates onto
    # the MoE-binding shard).  Times the planner + the donated collective —
    # the cost `SimResult.relax_time` models.
    from repro.core.state import Request
    cl = eng.cluster
    sched = eng.scheduler
    relax_cells = []
    for pages in page_counts:
        t = pages * page
        disp, total = [], []
        for r in range(reps + 1):        # rep 0 warms the compile bucket
            rid = 10_000 + pages * 100 + r
            cl.page_table.allocate(rid, {0: page, 1: t})
            req = Request(rid=rid, prompt_len=page + t, max_new_tokens=0)
            req.kv_binding, req.moe_binding, req.node = [0, 1], 0, 0
            req.status = "running"
            cl.active[rid] = req
            t0 = time.perf_counter()
            recs = sched.relax(cl, force=True)
            assert recs and recs[0].tokens_moved == t, (pages, recs)
            eng.state = eng._reshard(eng.state, recs[0].src_coords,
                                     recs[0].dst_coords)
            t1 = time.perf_counter()
            jax.block_until_ready(jax.tree.leaves(eng.state))
            t2 = time.perf_counter()
            if r > 0:
                disp.append((t1 - t0) * 1e6)
                total.append((t2 - t0) * 1e6)
            cl.active.pop(rid)
            cl.page_table.free_request(rid)
        relax_cells.append({"pages_reclaimed": pages, "tokens_moved": t,
                            "dispatch": _summ(disp),
                            "complete": _summ(total)})
        print(f"relax pages={pages:4d} tokens={t:5d}  "
              f"dispatch p50 {relax_cells[-1]['dispatch']['p50_us']:8.1f}us  "
              f"complete p50 {relax_cells[-1]['complete']['p50_us']:8.1f}us")
    return {
        "bench": "kv_reshard_latency_vs_pages",
        "arch": "tinyllama-1.1b(reduced nl=2)",
        "topology": {"instances": 2, "tp": 2, "page_size": page},
        "smoke": smoke,
        "cells": cells,
        "relax_cells": relax_cells,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_escalation.json"))
    args = ap.parse_args()
    out = run_bench(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
